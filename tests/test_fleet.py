"""Disaggregated prefill/decode fleet (ISSUE 9 tentpole).

Locks the launch/fleet_engine.py contract:

  * a 1-node combined fleet (handoff off) reproduces the bare
    ContinuousBatchingEngine EXACTLY — hex-identical report floats, the
    same event log and the same timeline event stream;
  * disaggregated runs finish every request, price every handoff as a
    phase="kv_handoff" C2CTransfer on the decode side, and attribute
    per-node reports (node_id / pool);
  * router edge cases: an all-busy prefill pool HOLDS arrivals in the
    backlog (never drops), a full decode node re-queues an out-of-blocks
    handoff (never drops), a permanently infeasible one re-routes or
    rejects;
  * autoscaling: a scale-up wake rides the handoff, so ClusterWake
    precedes the first kv_handoff C2CTransfer on the woken node's
    timeline.
"""
import copy
import dataclasses
import json
import math

import pytest

from repro.configs import get_config
from repro.core import PicnicSimulator
from repro.core.timeline import C2CTransfer, ClusterWake
from repro.launch import FleetConfig, ServingConfig, Trace
from repro.launch.fleet_engine import DECODE, PREFILL, FleetEngine, fleet_serve
from repro.launch.serving_engine import ContinuousBatchingEngine
from repro.runtime.kv_cache import KVCacheConfig


@pytest.fixture(scope="module")
def cfg():
    return get_config("llama3.2-1b")


def _trace(n=24, rate=40, prompt=256, max_new=32, seed=0, **kw):
    return Trace.poisson(n, rate_rps=rate, seed=seed, prompt_len=prompt,
                         max_new=max_new, **kw)


def _hexdict(obj) -> dict:
    d = dataclasses.asdict(obj)
    d.pop("queue_depth", None)
    return {k: (v.hex() if isinstance(v, float) else v)
            for k, v in d.items()}


def _hexevents(timeline):
    out = []
    for e in timeline.events:
        out.append(tuple(v.hex() if isinstance(v, float) else v
                         for v in dataclasses.astuple(e)))
    return out


# ---------------------------------------------------------------------------
# Degenerate identity: 1-node combined fleet == bare engine
# ---------------------------------------------------------------------------

def test_one_node_combined_fleet_identical_to_bare_engine(cfg):
    """The fleet layer adds NOTHING on the degenerate path: same report
    (hex floats), same event log, same timeline event stream, same
    final clock."""
    ecfg = ServingConfig(max_batch=4, ccpg=True)
    trace = _trace()

    bare = ContinuousBatchingEngine(cfg, sim=PicnicSimulator(), engine=ecfg)
    rep = bare.run([copy.copy(r) for r in trace])

    fe = FleetEngine(cfg,
                     FleetConfig(n_prefill=1, n_decode=0, handoff=False,
                                 engine=ecfg),
                     sim=PicnicSimulator())
    frep = fe.run([copy.copy(r) for r in trace])

    node = fe.nodes[0]
    nrep = frep.node_reports[0]
    # single-node fleet: attribution stays None, row() omits it — the
    # BENCH artifact schema is unchanged
    assert nrep.node_id is None and nrep.pool is None
    assert "node_id" not in nrep.row()
    assert _hexdict(nrep) == _hexdict(rep)
    assert node.eng.events == bare.events
    assert _hexevents(node.eng.timeline) == _hexevents(bare.timeline)
    assert node.eng.timeline.now.hex() == bare.timeline.now.hex()
    # fleet aggregate mirrors the single node
    assert frep.finished == rep.finished
    assert frep.tokens_generated == rep.tokens_generated
    assert frep.handoffs == 0 and frep.handoff_bytes == 0


def test_fleet_serve_wrapper_matches_engine(cfg):
    ecfg = ServingConfig(max_batch=4)
    trace = _trace(n=8)
    r1 = fleet_serve(cfg, [copy.copy(r) for r in trace],
                     fleet=FleetConfig(engine=ecfg), sim=PicnicSimulator())
    r2 = FleetEngine(cfg, FleetConfig(engine=ecfg),
                     sim=PicnicSimulator()).run([copy.copy(r) for r in trace])
    assert r1.row() == r2.row()


# ---------------------------------------------------------------------------
# Disaggregation: handoff accounting + attribution
# ---------------------------------------------------------------------------

def test_disagg_finishes_all_and_prices_handoffs(cfg):
    ecfg = ServingConfig(max_batch=4, ccpg=True)
    fe = FleetEngine(cfg,
                     FleetConfig(n_prefill=1, n_decode=1, engine=ecfg),
                     sim=PicnicSimulator())
    trace = _trace()
    rep = fe.run([copy.copy(r) for r in trace])
    assert rep.finished == len(trace)
    assert rep.rejected == 0
    assert rep.handoffs == len(trace)       # every request decodes remotely
    assert rep.handoff_bytes > 0
    # the decode node's timeline carries one kv_handoff C2CTransfer per
    # handoff, and their wire bytes sum to the fleet's accounting
    dc = next(n for n in fe.nodes if n.pool == DECODE)
    c2c = [e for e in dc.eng.timeline.events
           if isinstance(e, C2CTransfer) and e.phase == "kv_handoff"]
    assert len(c2c) == rep.handoffs
    assert sum(e.nbytes for e in c2c) == rep.handoff_bytes
    assert all(e.source == "fleet" for e in c2c)
    # multi-node run: per-node attribution is set and surfaces in row()
    for nr, n in zip(rep.node_reports, fe.nodes):
        assert nr.node_id == n.node_id and nr.pool == n.pool
        assert nr.row()["pool"] in (PREFILL, DECODE)
    # TTFT comes from the prefill node, full latency from the decode
    # node — both present in the fleet aggregate
    assert rep.p50_ttft_s < rep.p50_latency_s


def test_handoff_bytes_analytic_pricing(cfg):
    """With no paged cache the wire bytes are context * bytes/token
    (Table-II-style analytic), overridable per fleet."""
    bpt = 1000
    ecfg = ServingConfig(max_batch=4)
    fe = FleetEngine(cfg,
                     FleetConfig(n_prefill=1, n_decode=1, engine=ecfg,
                                 handoff_bytes_per_token=bpt),
                     sim=PicnicSimulator())
    trace = _trace(n=6)
    rep = fe.run([copy.copy(r) for r in trace])
    # context at handoff = prompt + the prefill-emitted first token
    expect = sum((r.prompt_len + 1) * bpt for r in trace)
    assert rep.handoff_bytes == expect


def test_max_new_one_requests_finish_at_prefill(cfg):
    """A request that only wants one token never ships KV anywhere."""
    ecfg = ServingConfig(max_batch=4)
    fe = FleetEngine(cfg,
                     FleetConfig(n_prefill=1, n_decode=1, engine=ecfg),
                     sim=PicnicSimulator())
    trace = _trace(n=6, max_new=1)
    rep = fe.run([copy.copy(r) for r in trace])
    assert rep.finished == len(trace)
    assert rep.handoffs == 0 and rep.handoff_bytes == 0


# ---------------------------------------------------------------------------
# Router edge cases
# ---------------------------------------------------------------------------

def test_all_prefill_pool_busy_holds_backlog(cfg):
    """Every awake prefill queue full -> the router HOLDS the arrival in
    its backlog and re-dispatches after node steps; nothing drops."""
    ecfg = ServingConfig(max_batch=2, queue_limit=2)
    fe = FleetEngine(cfg,
                     FleetConfig(n_prefill=1, n_decode=1, engine=ecfg),
                     sim=PicnicSimulator())
    # a burst: 16 arrivals at effectively the same instant swamp a
    # queue_limit=2 node many times over
    trace = _trace(n=16, rate=100000, prompt=256, max_new=8)
    rep = fe.run([copy.copy(r) for r in trace])
    assert rep.finished == len(trace)
    assert rep.rejected == 0


def test_router_rejects_past_its_own_bound(cfg):
    ecfg = ServingConfig(max_batch=2, queue_limit=1)
    fe = FleetEngine(cfg,
                     FleetConfig(n_prefill=1, n_decode=1, engine=ecfg,
                                 queue_limit=2),
                     sim=PicnicSimulator())
    trace = _trace(n=12, rate=100000, prompt=256, max_new=8)
    rep = fe.run([copy.copy(r) for r in trace])
    assert rep.rejected > 0
    assert rep.finished + rep.rejected == len(trace)


def test_decode_oob_requeues_instead_of_dropping(cfg):
    """A decode node out of KV blocks keeps the handoff queued until a
    resident finishes — re-queued, never dropped."""
    # one resident context (256 prompt + 32 new ~ 18 blocks) fits, two
    # do not -> the second import must wait for the first to free
    kvc = KVCacheConfig(n_blocks=24, block_tokens=16, dram_blocks=0,
                        bytes_per_token=4096)
    ecfg = ServingConfig(max_batch=4, kv_cache=kvc,
                         chunked_prefill_tokens=128)
    fe = FleetEngine(cfg,
                     FleetConfig(n_prefill=1, n_decode=1, engine=ecfg),
                     sim=PicnicSimulator())
    trace = _trace(n=4, rate=100000, prompt=256, max_new=32)
    rep = fe.run([copy.copy(r) for r in trace])
    assert rep.finished == len(trace)
    assert rep.rejected == 0
    assert rep.requeued_handoffs >= 1


def test_infeasible_handoff_reroutes_or_rejects(cfg):
    """_reroute_handoff: a context no decode node can ever hold is
    rejected (not dropped silently, not retried forever); with a
    feasible sibling it pays a second hop instead."""
    kvc = KVCacheConfig(n_blocks=24, block_tokens=16, dram_blocks=0,
                        bytes_per_token=4096)
    ecfg = ServingConfig(max_batch=4, kv_cache=kvc)
    fe = FleetEngine(cfg,
                     FleetConfig(n_prefill=1, n_decode=2, engine=ecfg),
                     sim=PicnicSimulator())
    fe.run([copy.copy(r) for r in _trace(n=2, max_new=4)])  # prime state

    nodes = [n for n in fe.nodes if n.pool == DECODE]
    # a context far past every node's capacity: reject
    big = _trace(n=1)[0]
    big.context = 10_000
    fe._records[big.request_id] = {"req": big, "final": big,
                                   "rejected": False, "eta": 0.0}
    before = fe._fleet_rejected
    fe._reroute_handoff(big, 123, 1e-6, now=0.0, exclude=nodes[0])
    assert fe._records[big.request_id]["rejected"]
    assert fe._fleet_rejected == before + 1
    # a small context re-routes to the sibling decode node
    small = _trace(n=1, seed=1)[0]
    small.request_id = 999
    small.context = 64
    fe._records[999] = {"req": small, "final": small,
                        "rejected": False, "eta": 0.0}
    rerouted_before = fe.rerouted
    fe._reroute_handoff(small, 123, 1e-6, now=0.0, exclude=nodes[0])
    assert fe.rerouted == rerouted_before + 1
    assert any(h[2] is small for h in nodes[1].handoffs)


def test_slo_admission_rejects_unreachable_deadlines(cfg):
    """Opt-in SLO gate: a TTFT deadline the least-loaded prefill node
    already cannot meet rejects at the router, before burning prefill."""
    ecfg = ServingConfig(max_batch=2)
    fe = FleetEngine(cfg,
                     FleetConfig(n_prefill=1, n_decode=1, engine=ecfg,
                                 slo_admission=True),
                     sim=PicnicSimulator())
    # deadline far below one prefill's latency: everything but the
    # impossible is rejected up front
    trace = _trace(n=8, rate=2000, prompt=2048, max_new=8,
                   deadline_ttft=1e-6)
    rep = fe.run([copy.copy(r) for r in trace])
    assert rep.slo_rejected > 0
    assert rep.finished + rep.rejected == len(trace)


# ---------------------------------------------------------------------------
# Autoscaling
# ---------------------------------------------------------------------------

def test_wake_rides_handoff_ordering(cfg):
    """Scale-up during a handoff: the woken decode node's timeline shows
    the ClusterWake BEFORE its first kv_handoff C2CTransfer — the wake
    starts at the prefill finish, the KV lands after."""
    ecfg = ServingConfig(max_batch=4, ccpg=True)
    fe = FleetEngine(cfg,
                     FleetConfig(n_prefill=2, n_decode=2, engine=ecfg,
                                 autoscale=True, min_awake=1,
                                 scale_up_queue=2),
                     sim=PicnicSimulator())
    rep = fe.run([copy.copy(r) for r in _trace(n=24, rate=40)])
    assert rep.finished == 24
    assert rep.wakes > 0
    # the second decode node started asleep; if traffic woke it, its
    # event stream must open with the wake, not the transfer
    woken = [n for n in fe.nodes
             if n.pool == DECODE and n.node_id >= 3 and n.wakes > 0]
    assert woken, "expected the initially-asleep decode node to wake"
    for n in woken:
        evs = n.eng.timeline.events
        i_wake = next(i for i, e in enumerate(evs)
                      if isinstance(e, ClusterWake))
        i_kv = next(i for i, e in enumerate(evs)
                    if isinstance(e, C2CTransfer)
                    and e.phase == "kv_handoff")
        assert i_wake < i_kv


def test_autoscale_off_never_wakes(cfg):
    ecfg = ServingConfig(max_batch=4)
    fe = FleetEngine(cfg,
                     FleetConfig(n_prefill=2, n_decode=2, engine=ecfg),
                     sim=PicnicSimulator())
    rep = fe.run([copy.copy(r) for r in _trace(n=12)])
    assert rep.wakes == 0
    assert all(not isinstance(e, ClusterWake)
               for n in fe.nodes for e in n.eng.timeline.events)


# ---------------------------------------------------------------------------
# Reporting / trace export
# ---------------------------------------------------------------------------

def test_report_row_and_summary(cfg):
    fe = FleetEngine(cfg, FleetConfig(engine=ServingConfig(max_batch=4)),
                     sim=PicnicSimulator())
    rep = fe.run([copy.copy(r) for r in _trace(n=8)])
    row = rep.row()
    assert row["nodes"] == 2 and row["handoff"] is True
    assert row["finished"] == 8
    assert isinstance(rep.summary(), str) and "FleetReport" in rep.summary()
    assert not math.isnan(rep.tokens_per_J)


def test_merged_chrome_trace_one_process_per_node(cfg, tmp_path):
    fe = FleetEngine(cfg, FleetConfig(engine=ServingConfig(max_batch=4)),
                     sim=PicnicSimulator())
    fe.run([copy.copy(r) for r in _trace(n=6)])
    path = tmp_path / "fleet_trace.json"
    fe.save_chrome_trace(path)
    doc = json.loads(path.read_text())
    pids = {e["pid"] for e in doc["traceEvents"]}
    assert pids == {0, 1}
    names = {e["args"]["name"] for e in doc["traceEvents"]
             if e.get("ph") == "M" and e.get("name") == "process_name"}
    assert names == {"node0:prefill", "node1:decode"}


def test_rerun_is_deterministic(cfg):
    fc = FleetConfig(n_prefill=1, n_decode=1,
                     engine=ServingConfig(max_batch=4, ccpg=True))
    trace = _trace(n=12)
    r1 = FleetEngine(cfg, fc, sim=PicnicSimulator()).run(
        [copy.copy(r) for r in trace])
    r2 = FleetEngine(cfg, fc, sim=PicnicSimulator()).run(
        [copy.copy(r) for r in trace])
    assert r1.row() == r2.row()
    assert _hexdict(r1.node_reports[0]) == _hexdict(r2.node_reports[0])
