"""Deterministic fallback for ``hypothesis`` when it is not installed.

CI images for this repo have no network access, so ``hypothesis`` may be
absent.  Rather than erroring at collection time, the five property-test
modules degrade to *seeded-example* tests: ``install()`` (called from
``conftest.py``) registers stub ``hypothesis`` / ``hypothesis.strategies``
modules implementing exactly the subset this suite uses —

  * ``@given(kw=strategy, ...)`` with keyword strategies
  * ``@settings(max_examples=..., deadline=...)``
  * ``st.integers(lo, hi)``, ``st.floats(lo, hi)``, ``st.booleans()``,
    ``st.sampled_from(seq)``

Each ``@given`` test then runs a fixed set of examples: first every
strategy pinned at its lower bound (the classic edge case), then
pseudo-random draws from a per-test seeded RNG, so failures are exactly
reproducible across runs and machines.  This is NOT a property-based
explorer — no shrinking, no coverage guidance.  Install the real thing
(``pip install -e .[test]``) to get those back; when ``hypothesis`` is
importable this module is a no-op.

``HYP_COMPAT_MAX_EXAMPLES`` caps the per-test example count (default 10)
so the fallback stays fast even for tests that request ``max_examples=100``.
"""
from __future__ import annotations

import functools
import inspect
import os
import random
import sys
import types

_DEFAULT_EXAMPLES = 10


class SearchStrategy:
    """A value generator: a lower-bound example plus a seeded draw."""

    def __init__(self, lo_example, draw):
        self._lo_example = lo_example
        self._draw = draw

    def lo(self):
        return self._lo_example

    def draw(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int = 0, max_value: int = 1 << 16) -> SearchStrategy:
    return SearchStrategy(min_value,
                          lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float = 0.0, max_value: float = 1.0,
           **_kw) -> SearchStrategy:
    return SearchStrategy(float(min_value),
                          lambda rng: rng.uniform(min_value, max_value))


def booleans() -> SearchStrategy:
    return SearchStrategy(False, lambda rng: bool(rng.getrandbits(1)))


def sampled_from(elements) -> SearchStrategy:
    seq = list(elements)
    if not seq:
        raise ValueError("sampled_from requires a non-empty sequence")
    return SearchStrategy(seq[0], lambda rng: seq[rng.randrange(len(seq))])


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None,
             **_kw):
    """Applied OUTSIDE @given in this suite, so it decorates the @given
    wrapper and just annotates it with the requested example count."""
    def deco(fn):
        fn._hyp_max_examples = max_examples
        return fn
    return deco


def given(**strategies):
    if not strategies:
        raise TypeError("hyp-compat given() supports keyword strategies only")

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cap = int(os.environ.get("HYP_COMPAT_MAX_EXAMPLES",
                                     str(_DEFAULT_EXAMPLES)))
            n = min(getattr(wrapper, "_hyp_max_examples", _DEFAULT_EXAMPLES),
                    max(cap, 1))
            # example 0: all strategies at their lower bound
            fn(*args, **dict(kwargs,
                             **{k: s.lo() for k, s in strategies.items()}))
            rng = random.Random(
                f"hyp-compat::{fn.__module__}.{fn.__qualname__}")
            for _ in range(n - 1):
                fn(*args, **dict(kwargs,
                                 **{k: s.draw(rng)
                                    for k, s in strategies.items()}))
        # pytest must not see the strategy kwargs as fixtures: expose only
        # the non-strategy parameters (if any) of the original function
        params = [p for name, p in
                  inspect.signature(fn).parameters.items()
                  if name not in strategies]
        wrapper.__signature__ = inspect.Signature(params)
        del wrapper.__wrapped__
        wrapper.hypothesis_compat_fallback = True
        return wrapper
    return deco


def install() -> bool:
    """Register the stub as ``hypothesis`` in sys.modules if (and only if)
    the real package is unavailable.  Returns True if the stub was used."""
    if "hypothesis" in sys.modules:
        return getattr(sys.modules["hypothesis"], "_compat_fallback", False)
    try:
        import hypothesis  # noqa: F401  (real package wins)
        return False
    except ImportError:
        pass
    hyp = types.ModuleType("hypothesis")
    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from"):
        setattr(strat, name, globals()[name])
    strat.SearchStrategy = SearchStrategy
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = strat
    hyp._compat_fallback = True
    hyp.__version__ = "0.0.0+compat"
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat
    return True
