"""Bench-regression gate: direction-aware metric handling and the
host-calibration guard for wall-clock benches (benchmarks/
check_regression.py is loaded from its file — benchmarks/ is a script
directory, not a package)."""
import importlib.util
from pathlib import Path

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "check_regression",
    Path(__file__).resolve().parents[1] / "benchmarks"
    / "check_regression.py")
cr = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(cr)


def test_metric_direction_families():
    assert cr.metric_direction("speedup.serving") == "higher"
    assert cr.metric_direction("tokens_per_s.b8_ccpg0") == "higher"
    assert cr.metric_direction("efficiency_tok_J.llama") == "higher"
    assert cr.metric_direction("wall_ms.serving_fast") == "lower"
    # informational, never gated
    assert cr.metric_direction("p99_latency_s.x") == ""
    assert cr.metric_direction("sim_tokens_per_wall_s.serving_fast") == ""
    assert cr.metric_direction("events_per_wall_s.serving") == ""


def test_headline_metrics_flattening_and_filter():
    doc = {"metrics": {"speedup": {"a": 2.0}, "wall_ms": {"a_fast": 5.0},
                       "notes": {"p99_latency_s": 1.0},
                       "flag": True}}
    m = cr.headline_metrics(doc)
    assert m == {"speedup.a": 2.0, "wall_ms.a_fast": 5.0}


def test_hosts_comparable_guard():
    doc = {"host_ops_per_s": 1000.0, "smoke": False}
    assert cr.hosts_comparable(doc, dict(doc))
    assert cr.hosts_comparable(doc, {"host_ops_per_s": 1200.0,
                                     "smoke": False})      # within 30%
    assert not cr.hosts_comparable(doc, {"host_ops_per_s": 2000.0,
                                         "smoke": False})  # 2x host
    assert not cr.hosts_comparable(doc, {"host_ops_per_s": 1000.0,
                                         "smoke": True})   # workload size
    # simulated benches carry no calibration -> always comparable
    assert cr.hosts_comparable({}, {})
    assert cr.hosts_comparable({}, doc)


def _gate(tmp_path, monkeypatch, base_doc, cur_doc, tolerance=0.10):
    import json
    bench = tmp_path / "bench"
    baseline = bench / "baseline"
    baseline.mkdir(parents=True, exist_ok=True)
    (baseline / "BENCH_x.json").write_text(json.dumps(base_doc))
    (bench / "BENCH_x.json").write_text(json.dumps(cur_doc))
    monkeypatch.setattr(cr, "BENCH_DIR", bench)
    monkeypatch.setattr(cr, "BASELINE_DIR", baseline)
    return cr.compare(tolerance)


def _doc(speedup, wall_ms, host=1000.0):
    return {"host_ops_per_s": host, "smoke": False,
            "metrics": {"speedup": {"serving": speedup},
                        "wall_ms": {"serving_fast": wall_ms}}}


def test_gate_passes_within_tolerance(tmp_path, monkeypatch):
    # wall-clock benches use the widened WALL_BENCH_TOL (measured-time
    # noise), so a -30% speedup wobble passes
    assert _gate(tmp_path, monkeypatch, _doc(10.0, 5.0),
                 _doc(7.0, 6.5)) == 0


def test_gate_fails_on_speedup_drop(tmp_path, monkeypatch):
    assert _gate(tmp_path, monkeypatch, _doc(10.0, 5.0),
                 _doc(4.0, 5.0)) == 1


def test_gate_fails_on_wall_clock_slowdown(tmp_path, monkeypatch):
    """The direction-aware half: wall_ms RISING beyond tolerance fails
    even while every higher-is-better metric is fine."""
    assert _gate(tmp_path, monkeypatch, _doc(10.0, 5.0),
                 _doc(10.0, 9.0)) == 1


def test_gate_simulated_benches_keep_tight_tolerance(tmp_path,
                                                     monkeypatch):
    """Docs WITHOUT a host calibration are deterministic simulated
    benches: the plain 10% tolerance applies."""
    base = {"metrics": {"tokens_per_s": {"b8": 100.0}}}
    assert _gate(tmp_path, monkeypatch, base,
                 {"metrics": {"tokens_per_s": {"b8": 85.0}}}) == 1
    assert _gate(tmp_path, monkeypatch, base,
                 {"metrics": {"tokens_per_s": {"b8": 95.0}}}) == 0


def test_gate_skips_wall_bench_on_foreign_host(tmp_path, monkeypatch):
    """A 3x-slower host is not a code regression: the whole wall-clock
    bench is skipped (microbench --min-speedup floors foreign hosts)."""
    assert _gate(tmp_path, monkeypatch, _doc(10.0, 5.0),
                 _doc(6.0, 50.0, host=300.0)) == 0


def test_gate_fails_on_missing_current_artifact(tmp_path, monkeypatch):
    import json
    bench = tmp_path / "bench"
    baseline = bench / "baseline"
    baseline.mkdir(parents=True)
    (baseline / "BENCH_x.json").write_text(json.dumps(_doc(10.0, 5.0)))
    monkeypatch.setattr(cr, "BENCH_DIR", bench)
    monkeypatch.setattr(cr, "BASELINE_DIR", baseline)
    assert cr.compare(0.10) == 1
