"""TimelineIR: golden byte-identity regression (default config pre/post
refactor), opt-in overlap & dynamic-CCPG deltas, Chrome-trace export."""
import dataclasses
import json
from pathlib import Path

import pytest

from repro.configs import get_config
from repro.core import (EVENT_CATEGORIES, C2CTransfer, ClusterSleep,
                        ClusterWake, ComputeSpan, EnergySample,
                        PicnicSimulator, Timeline, TokenEmit, TrafficTrace)
from repro.launch.serving_engine import (ContinuousBatchingEngine,
                                         ServingConfig, poisson_trace,
                                         replay_trace, serve_trace)

GOLDEN = json.loads(
    (Path(__file__).parent / "golden" / "timeline_golden.json").read_text())


def _hexdict(obj) -> dict:
    d = dataclasses.asdict(obj)
    d.pop("queue_depth", None)
    # per-node attribution (ISSUE 9 fleet) stays None outside a fleet and
    # is absent from the committed golden — drop it exactly when unset
    for k in ("node_id", "pool"):
        if k in d and d[k] is None:
            d.pop(k)
    return {k: (v.hex() if isinstance(v, float) else v) for k, v in d.items()}


@pytest.fixture(scope="module")
def cfg():
    return get_config("llama3.2-1b")


# ---------------------------------------------------------------------------
# Golden regression: the default (no-overlap, static-CCPG) configuration is
# BYTE-IDENTICAL to the pre-refactor closed-form paths.  The golden file was
# captured from the seed code before core/timeline.py existed.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("key", sorted(GOLDEN["table_ii"]))
def test_simulator_golden_byte_identical(key):
    arch, ctx, cc = key.split("/")
    sim = PicnicSimulator()
    r = sim.run(get_config(arch), int(ctx), int(ctx),
                ccpg=(cc == "ccpg=True"))
    assert _hexdict(r) == GOLDEN["table_ii"][key]


@pytest.mark.parametrize("key", sorted(GOLDEN["serving"]))
def test_serving_golden_byte_identical(key, cfg):
    trace = poisson_trace(24, rate_rps=40, seed=0, prompt_len=256,
                          max_new=32)
    rep = serve_trace(cfg, trace, max_batch=4, ccpg=(key == "ccpg=True"))
    assert _hexdict(rep) == GOLDEN["serving"][key]


# ---------------------------------------------------------------------------
# Timeline accumulator semantics
# ---------------------------------------------------------------------------

def test_advancing_vs_concurrent_appends():
    tl = Timeline()
    tl.compute(1.0, kind="prefill", power_W=2.0, batch=3)
    assert tl.now == 1.0 and tl.busy_s == 1.0 and tl.energy_J == 2.0
    assert tl.occupancy_s == 3.0
    tl.c2c(4096, phase="decode")            # concurrent: no time passes
    tl.token(5, request_id=7)
    assert tl.now == 1.0 and tl.c2c_bytes == 4096 and tl.tokens == 5
    tl.sleep(2.0, power_W=0.5)
    assert tl.now == 3.0 and tl.idle_s == 2.0
    assert tl.energy_J == pytest.approx(2.0 + 1.0)
    tl.wake(0.25, power_W=4.0, cycles=100)
    assert tl.now == 3.25 and tl.energy_J == pytest.approx(3.0 + 1.0)
    assert tl.busy_s == 1.25


def test_energy_is_span_integrated_not_average_power():
    """Two spans at different powers: the integral differs from
    avg(power) * wall whenever durations are unequal — the whole point
    of the IR."""
    tl = Timeline()
    tl.compute(3.0, kind="decode", power_W=10.0)
    tl.sleep(1.0, power_W=2.0)
    assert tl.energy_J == pytest.approx(32.0)
    naive = (10.0 + 2.0) / 2 * tl.now
    assert tl.energy_J != pytest.approx(naive)


def test_cycles_sum_is_exact_ints():
    tl = Timeline()
    tl.compute(0.1, kind="decode", cycles=3)
    tl.compute(0.1, kind="decode", cycles=5)
    tl.compute(0.1, kind="prefill", cycles=11)
    tl.wake(0.1, cycles=7)
    assert tl.cycles(ComputeSpan, kind="decode") == 8
    assert tl.cycles(ComputeSpan, kind="prefill") == 11
    assert tl.cycles(ClusterWake) == 7
    assert tl.cycles(ComputeSpan) == 19


def test_sleep_annotation_does_not_advance_or_charge():
    tl = Timeline()
    tl.compute(1.0, kind="decode", power_W=1.0)
    e0 = tl.energy_J
    tl.sleep(1.0, t0=0.0, advance=False, power_W=99.0)
    assert tl.now == 1.0 and tl.energy_J == e0 and tl.idle_s == 0.0
    assert tl.count(ClusterSleep) == 1


def test_traffic_trace_from_timeline(cfg):
    sim = PicnicSimulator()
    tl = Timeline()
    trace = sim.c2c_trace(cfg, n_tokens=2, context=128, timeline=tl)
    assert isinstance(trace, TrafficTrace)
    assert len(trace.events) == tl.count(C2CTransfer) > 0
    assert trace.events == TrafficTrace.from_timeline(tl).events
    assert tl.count(TokenEmit) == 2


# ---------------------------------------------------------------------------
# Opt-in knobs measurably change time-resolved behavior
# ---------------------------------------------------------------------------

def test_overlap_hides_c2c_and_speeds_decode(cfg):
    sim = PicnicSimulator()
    base = sim.run(cfg, 512, 512)
    ov = sim.run(cfg, 512, 512, overlap=1.0)
    half = sim.run(cfg, 512, 512, overlap=0.5)
    assert ov.decode_s < half.decode_s < base.decode_s
    assert ov.throughput_tps > base.throughput_tps
    assert ov.prefill_s == base.prefill_s          # prefill untouched
    assert ov.c2c_bytes_total == base.c2c_bytes_total  # traffic unchanged


def test_overlap_out_of_range_rejected(cfg):
    sim = PicnicSimulator()
    for bad in (-0.5, 1.5, 50):
        with pytest.raises(ValueError):
            sim.run(cfg, 512, 64, overlap=bad)
        with pytest.raises(ValueError):
            serve_trace(cfg, replay_trace([(0.0, 16, 2)]), max_batch=1,
                        overlap=bad)


def test_shared_timeline_anchors_runs_sequentially(cfg):
    """Two runs appended to ONE timeline must not stamp the second run's
    bursts/sleep annotations inside the first run's window."""
    sim = PicnicSimulator()
    tl = Timeline()
    sim.run(cfg, 256, 32, ccpg=True, timeline=tl)
    t_mid = tl.now
    n_mid = len(tl.events)
    sim.run(cfg, 256, 32, ccpg=True, timeline=tl)
    assert tl.now > t_mid
    for e in tl.events[n_mid:]:
        assert e.t0 >= t_mid                 # second run starts after first
    sleeps = [e for e in tl.events if isinstance(e, ClusterSleep)]
    assert len(sleeps) == 2
    assert sleeps[1].t0 == pytest.approx(t_mid)
    assert sleeps[1].dur_s == pytest.approx(tl.now - t_mid)


def test_overlap_zero_is_identity(cfg):
    sim = PicnicSimulator()
    assert dataclasses.asdict(sim.run(cfg, 512, 128, overlap=0.0)) \
        == dataclasses.asdict(sim.run(cfg, 512, 128))


def test_dynamic_ccpg_slows_decode_vs_static(cfg):
    """Dynamic mode exposes the full regulator-settle walk (wake_cycles
    stops being dead state), so decode is measurably slower than the
    pre-wake-residue static model."""
    sim = PicnicSimulator()
    static = sim.run(cfg, 512, 128, ccpg=True)
    dyn = sim.run(cfg, 512, 128, ccpg=True, dynamic_ccpg=True)
    assert dyn.decode_s > static.decode_s
    assert dyn.throughput_tps < static.throughput_tps
    assert dyn.prefill_s == static.prefill_s


def test_dynamic_ccpg_raises_serving_p99(cfg):
    kw = dict(rate_rps=40, seed=0, prompt_len=256, max_new=32)
    r_s = serve_trace(cfg, poisson_trace(24, **kw), max_batch=4, ccpg=True)
    r_d = serve_trace(cfg, poisson_trace(24, **kw), max_batch=4, ccpg=True,
                      dynamic_ccpg=True)
    assert r_d.p99_latency_s > r_s.p99_latency_s
    assert r_d.p99_ttft_s >= r_s.p99_ttft_s
    assert r_d.tokens_per_s < r_s.tokens_per_s


def test_engine_overlap_speeds_serving(cfg):
    kw = dict(rate_rps=40, seed=0, prompt_len=256, max_new=32)
    r0 = serve_trace(cfg, poisson_trace(24, **kw), max_batch=4)
    r1 = serve_trace(cfg, poisson_trace(24, **kw), max_batch=4, overlap=1.0)
    assert r1.tokens_per_s > r0.tokens_per_s


# ---------------------------------------------------------------------------
# Chrome-trace export
# ---------------------------------------------------------------------------

def _categories(trace_json):
    return {e.get("cat") for e in trace_json["traceEvents"] if "cat" in e}


def test_chrome_trace_roundtrips_with_all_categories(cfg, tmp_path):
    sim = PicnicSimulator()
    tl = Timeline()
    sim.run(cfg, 512, 64, ccpg=True, dynamic_ccpg=True, timeline=tl)
    path = tmp_path / "trace.json"
    tl.save_chrome_trace(path)
    d = json.loads(path.read_text())         # valid JSON round-trip
    assert {c.__name__ for c in EVENT_CATEGORIES} <= _categories(d)
    for e in d["traceEvents"]:
        if e["ph"] == "X":
            assert e["ts"] >= 0 and e["dur"] >= 0


def test_engine_timeline_exports_chrome_trace(cfg):
    eng = ContinuousBatchingEngine(
        cfg, engine=ServingConfig(max_batch=2, ccpg=True, dynamic_ccpg=True))
    eng.run(replay_trace([(0.0, 32, 4), (0.5, 32, 4)]))
    d = json.loads(json.dumps(eng.timeline.to_chrome_trace()))
    assert {c.__name__ for c in EVENT_CATEGORIES} <= _categories(d)
    # wall clock in the trace matches the report clock
    spans = [e for e in d["traceEvents"] if e["ph"] == "X"]
    assert max(e["ts"] + e["dur"] for e in spans) \
        == pytest.approx(eng.timeline.now * 1e6)


def test_engine_report_derives_from_timeline(cfg):
    """ServingReport and the timeline agree: one integrator."""
    eng = ContinuousBatchingEngine(cfg, engine=ServingConfig(max_batch=4))
    rep = eng.run(poisson_trace(12, rate_rps=50, seed=3, prompt_len=64,
                                max_new=8))
    tl = eng.timeline
    assert rep.wall_s == max(tl.now, 1e-12)
    assert rep.busy_s == tl.busy_s and rep.idle_s == tl.idle_s
    assert rep.tokens_generated == tl.tokens
    assert rep.c2c_bytes_total == tl.c2c_bytes
    assert rep.energy_J == pytest.approx(tl.energy_J
                                         + tl.c2c_energy_J(rep.wall_s))
    # spans cover the wall clock exactly: busy + idle == now
    assert tl.busy_s + tl.idle_s == pytest.approx(tl.now)
